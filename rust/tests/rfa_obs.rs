//! Observability determinism suite: pins the write-only contract of
//! `crate::obs` against the full serving stack (see "Observability and
//! the determinism contract" in the `rfa/serve` module docs):
//!
//! 1. **Obs never changes outputs.** The same workload (resampling,
//!    eviction churn) at maximum verbosity is bitwise-identical in its
//!    responses to the same workload with obs disabled — across worker
//!    thread counts and both precisions.
//! 2. **Telemetry artifacts are thread-count-invariant.** For a fixed
//!    workload and scripted fault schedule: the normalized event-ring
//!    sequence, the deterministic histograms (batch sizes, request
//!    rows), the latency histograms' *counts* (values are wall-clock,
//!    counts are schedule), and every counter agree across thread
//!    counts.
//! 3. **The exporters are byte-stable** — a golden test pins the
//!    Prometheus text exposition exactly.
//!
//! Plus: `PoolStats`/snapshot-byte counters as registry views, the
//! quarantine/unquarantine counter+event pair, and the per-head
//! kernel-quality gauges (ESS, Σ̂ anisotropy, epochs, frozen bytes)
//! after real resample epochs.

use std::path::PathBuf;

use darkformer::linalg::Matrix;
use darkformer::obs::{
    prometheus_text, Event, EventKind, ObsConfig, Registry,
};
use darkformer::rfa::engine::Head;
use darkformer::rfa::estimators::Sampling;
use darkformer::rfa::serve::{
    BatchScheduler, Fault, FaultRule, FaultyStore, FsStore, Precision,
    ResampleConfig, RetryPolicy, ServeConfig, SessionPool, StepRequest,
    StepResponse, StoreOp,
};
use darkformer::rfa::PrfEstimator;
use darkformer::rng::{GaussianExt, Pcg64};

const D: usize = 4;
const M: usize = 16;
const N_HEADS: usize = 2;
const DV: usize = 3;
const CHUNK: usize = 8;
const N_REQUESTS: usize = 4;
const L: usize = CHUNK * N_REQUESTS;
/// Resample epoch length: two boundaries inside every L-position stream.
const K_EPOCH: u64 = 16;

const SESSION_SEEDS: [u64; 3] = [101, 202, 303];

fn snapshot_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("rfa_obs_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn cfg(
    precision: Precision,
    threads: usize,
    memory_budget: usize,
    dir: PathBuf,
) -> ServeConfig {
    ServeConfig {
        est: PrfEstimator::new(D, M, Sampling::Isotropic),
        n_heads: N_HEADS,
        dv: DV,
        precision,
        chunk: CHUNK,
        threads,
        memory_budget,
        snapshot_dir: dir,
        resample: Some(ResampleConfig::every(K_EPOCH)),
    }
}

fn rows(l: usize, d: usize, scale: f64, rng: &mut Pcg64) -> Vec<Vec<f64>> {
    (0..l)
        .map(|_| rng.gaussian_vec(d).iter().map(|x| scale * x).collect())
        .collect()
}

fn stream_inputs(input_seed: u64) -> Vec<Head> {
    let mut rng = Pcg64::seed(input_seed);
    (0..N_HEADS)
        .map(|_| Head {
            q: rows(L, D, 0.3, &mut rng),
            k: rows(L, D, 0.3, &mut rng),
            v: Matrix::from_rows(&rows(L, DV, 1.0, &mut rng)),
        })
        .collect()
}

fn slice_heads(heads: &[Head], b: usize, e: usize) -> Vec<Head> {
    heads
        .iter()
        .map(|h| Head {
            q: h.q[b..e].to_vec(),
            k: h.k[b..e].to_vec(),
            v: h.v.row_block(b, e),
        })
        .collect()
}

/// Resident bytes of one fresh session — the one-session budget every
/// churn workload uses (so eviction/restore traffic is guaranteed).
fn one_session_bytes(precision: Precision, tag: &str) -> usize {
    let dir = snapshot_dir(tag);
    let mut pool = SessionPool::with_obs(
        cfg(precision, 1, 0, dir),
        Box::new(FsStore),
        ObsConfig::off(),
    );
    let id = pool.create_session(1).unwrap();
    pool.session_mut(id).unwrap().state_bytes()
}

fn tight_policy() -> RetryPolicy {
    RetryPolicy {
        quarantine_persistent: 2,
        quarantine_any: 6,
        backoff_base: 1,
        backoff_cap: 2,
    }
}

struct ObsRun {
    sched: BatchScheduler,
    ids: Vec<u64>,
    responses: Vec<StepResponse>,
}

/// Drive the three-session, four-segment resampling workload through a
/// one-session-budget pool (guaranteed eviction/restore churn) with the
/// given obs config and scripted fault schedule; drains to idle and
/// asserts the schedule quarantined nothing (use transient-only rules).
fn run_workload(
    precision: Precision,
    threads: usize,
    obs_cfg: ObsConfig,
    rules: Vec<FaultRule>,
    tag: &str,
) -> ObsRun {
    let budget = one_session_bytes(precision, &format!("{tag}_probe"));
    let dir = snapshot_dir(tag);
    let store = FaultyStore::new(Box::new(FsStore), Vec::new());
    let handle = store.handle();
    let mut pool = SessionPool::with_obs(
        cfg(precision, threads, budget, dir),
        Box::new(store),
        obs_cfg,
    );
    let ids: Vec<u64> = SESSION_SEEDS
        .iter()
        .map(|s| pool.create_session(*s).unwrap())
        .collect();
    // Arm the schedule only after the sessions exist, so scripted op
    // counts start at the workload's start (as the chaos suite does).
    handle.script(rules);
    let mut sched = BatchScheduler::with_policy(pool, tight_policy());
    let streams: Vec<Vec<Head>> =
        (0..ids.len() as u64).map(|s| stream_inputs(7000 + s)).collect();
    for r in 0..N_REQUESTS {
        for (id, stream) in ids.iter().zip(&streams) {
            let heads = slice_heads(stream, r * CHUNK, (r + 1) * CHUNK);
            sched.submit(StepRequest { session_id: *id, heads }).unwrap();
        }
    }
    let outcome = sched.run_until_idle();
    assert!(outcome.error.is_none(), "{tag}: {:?}", outcome.error);
    assert!(
        outcome.failures.is_empty(),
        "{tag}: this workload's schedules must not quarantine"
    );
    ObsRun { sched, ids, responses: outcome.responses }
}

/// Responses flattened to exact bits, in completion order (f32 outputs
/// widen exactly, so f64 bit equality is storage bit equality).
fn response_bits(
    responses: &[StepResponse],
) -> Vec<(u64, u64, u64, Vec<u64>)> {
    responses
        .iter()
        .map(|r| {
            let bits: Vec<u64> = r
                .outputs
                .iter()
                .flat_map(|o| {
                    o.to_f64().data().iter().map(|v| v.to_bits()).collect::<Vec<_>>()
                })
                .collect();
            (r.session_id, r.seq, r.start_position, bits)
        })
        .collect()
}

/// Event sequence with pool-unique path prefixes stripped (each run has
/// its own pool tag and snapshot dir), leaving the schedule-relevant
/// identity only.
fn normalize_events(events: &[Event]) -> Vec<String> {
    fn norm_path(path: &str) -> String {
        let name = std::path::Path::new(path)
            .file_name()
            .map(|n| n.to_string_lossy().into_owned())
            .unwrap_or_default();
        name.split_once("-session-")
            .map(|(_, s)| format!("session-{s}"))
            .unwrap_or_else(|| "probe".to_string())
    }
    events
        .iter()
        .map(|e| match &e.kind {
            EventKind::StoreFault { op, path } => {
                format!("store-fault op={op} path={}", norm_path(path))
            }
            EventKind::OrphanRetry { path, recovered } => format!(
                "orphan-retry recovered={recovered} path={}",
                norm_path(path)
            ),
            other => format!("{other}"),
        })
        .collect()
}

// ---------------------------------------------------------------- tests

/// Property 1: obs at maximum verbosity changes no output bits relative
/// to obs disabled — across thread counts and precisions, on a workload
/// with resample epochs and eviction/restore churn.
#[test]
fn obs_full_outputs_bitwise_identical_to_off() {
    for precision in [Precision::F64, Precision::F32] {
        let ptag = match precision {
            Precision::F64 => "f64",
            Precision::F32 => "f32",
        };
        for threads in [1usize, 4] {
            let off = run_workload(
                precision,
                threads,
                ObsConfig::off(),
                Vec::new(),
                &format!("bits_off_{ptag}_t{threads}"),
            );
            let full = run_workload(
                precision,
                threads,
                ObsConfig::full(),
                Vec::new(),
                &format!("bits_full_{ptag}_t{threads}"),
            );
            assert_eq!(off.ids, full.ids);
            assert_eq!(
                response_bits(&off.responses),
                response_bits(&full.responses),
                "{ptag}/threads={threads}: obs level changed output bits"
            );
            // And the obs run did collect real telemetry.
            let obs = full.sched.obs();
            assert!(obs.evictions.get() > 0 && obs.restores.get() > 0);
            assert!(obs.resample_epochs.get() > 0);
        }
    }
}

/// Property 2: for a fixed scripted fault schedule, the normalized event
/// sequence, the deterministic histograms' bucket counts, the latency
/// histograms' counts, and every counter are identical across worker
/// thread counts.
#[test]
fn telemetry_artifacts_are_thread_count_invariant() {
    // Transient blips on reads and writes: enough to fire store-fault,
    // degraded-enter/exit and retry machinery without quarantining.
    let rules = || {
        vec![
            FaultRule::on(StoreOp::Read, Fault::Transient).skip(1).fires(2),
            FaultRule::on(StoreOp::Write, Fault::Transient).skip(4).fires(1),
        ]
    };
    let collect = |threads: usize| {
        let run = run_workload(
            Precision::F32,
            threads,
            ObsConfig::full(),
            rules(),
            &format!("invariant_t{threads}"),
        );
        let obs = run.sched.obs().clone();
        let counters: Vec<(String, u64)> = [
            ("evictions", obs.evictions.get()),
            ("restores", obs.restores.get()),
            ("bytes_written", obs.snapshot_bytes_written.get()),
            ("bytes_read", obs.snapshot_bytes_read.get()),
            ("failures", obs.snapshot_failures.get()),
            ("degraded_transitions", obs.degraded_transitions.get()),
            ("requests", obs.requests_completed.get()),
            ("rows", obs.rows_served.get()),
            ("ticks", obs.ticks.get()),
            ("epochs", obs.resample_epochs.get()),
        ]
        .map(|(k, v)| (k.to_string(), v))
        .to_vec();
        let latency_counts = vec![
            obs.tick_ms.count(),
            obs.forward_ms.count(),
            obs.snapshot_io_ms.count(),
            obs.resample_ms.count(),
        ];
        (
            response_bits(&run.responses),
            normalize_events(&obs.drain_events()),
            obs.batch_sessions.bucket_counts(),
            obs.request_rows.bucket_counts(),
            latency_counts,
            counters,
        )
    };
    let (bits1, events1, batch1, rows1, lat1, counters1) = collect(1);
    let (bits4, events4, batch4, rows4, lat4, counters4) = collect(4);
    assert_eq!(bits1, bits4, "outputs moved with thread count");
    assert_eq!(events1, events4, "event sequence moved with thread count");
    assert_eq!(batch1, batch4, "batch-size histogram moved");
    assert_eq!(rows1, rows4, "request-rows histogram moved");
    assert_eq!(lat1, lat4, "latency histogram counts moved");
    assert_eq!(counters1, counters4, "counters moved with thread count");

    // The schedule actually produced the signals this test is about.
    assert!(events1.iter().any(|e| e.starts_with("eviction")));
    assert!(events1.iter().any(|e| e.starts_with("restore")));
    assert!(events1.iter().any(|e| e.starts_with("resample-epoch")));
    assert!(events1.iter().any(|e| e.starts_with("store-fault")));
    assert!(events1.iter().any(|e| e.starts_with("degraded-enter")));
    assert!(
        counters1.iter().any(|(k, v)| k == "failures" && *v >= 3),
        "the scripted faults must be counted: {counters1:?}"
    );
}

/// Property 3: the Prometheus text exposition is pinned byte-for-byte.
#[test]
fn prometheus_exporter_golden() {
    let reg = Registry::new();
    reg.counter("rfa_test_total", "A test counter").add(3);
    reg.gauge("rfa_test_gauge", "A test gauge").set(2.5);
    reg.gauge_labeled(
        "rfa_head_ess",
        "session=\"0\",head=\"1\"".to_string(),
        "Effective sample size",
    )
    .set(12.0);
    let h = reg.histogram("rfa_test_ms", "A test histogram", &[1.0, 2.0]);
    h.observe(0.5);
    h.observe(1.5);
    h.observe(5.0);
    let expected = "\
# HELP rfa_test_total A test counter
# TYPE rfa_test_total counter
rfa_test_total 3
# HELP rfa_test_gauge A test gauge
# TYPE rfa_test_gauge gauge
rfa_test_gauge 2.5
# HELP rfa_head_ess Effective sample size
# TYPE rfa_head_ess gauge
rfa_head_ess{session=\"0\",head=\"1\"} 12
# HELP rfa_test_ms A test histogram
# TYPE rfa_test_ms histogram
rfa_test_ms_bucket{le=\"1\"} 1
rfa_test_ms_bucket{le=\"2\"} 2
rfa_test_ms_bucket{le=\"+Inf\"} 3
rfa_test_ms_sum 7
rfa_test_ms_count 3
";
    assert_eq!(prometheus_text(&reg), expected);
}

/// `PoolStats` is a view over the registry, the snapshot byte counters
/// track real traffic, and quarantine/unquarantine transitions are
/// counted and ring-logged.
#[test]
fn pool_stats_view_bytes_and_quarantine_counters() {
    let budget = one_session_bytes(Precision::F64, "quar_probe");
    let dir = snapshot_dir("quar");
    let store = FaultyStore::new(Box::new(FsStore), Vec::new());
    let handle = store.handle();
    let mut pool = SessionPool::with_obs(
        cfg(Precision::F64, 1, budget, dir),
        Box::new(store),
        ObsConfig::full(),
    );
    let ids: Vec<u64> = SESSION_SEEDS
        .iter()
        .map(|s| pool.create_session(*s).unwrap())
        .collect();
    // Session 0's snapshot reads fail persistently: the scheduler must
    // quarantine it while the other two keep serving.
    handle.script(vec![FaultRule::on(StoreOp::Read, Fault::Persistent)
        .on_path(format!("session-{}.dkft", ids[0]))]);
    let mut sched = BatchScheduler::with_policy(pool, tight_policy());
    let streams: Vec<Vec<Head>> =
        (0..ids.len() as u64).map(|s| stream_inputs(8000 + s)).collect();
    for r in 0..2 {
        for (id, stream) in ids.iter().zip(&streams) {
            let heads = slice_heads(stream, r * CHUNK, (r + 1) * CHUNK);
            sched.submit(StepRequest { session_id: *id, heads }).unwrap();
        }
    }
    let outcome = sched.run_until_idle();
    assert!(outcome.error.is_none());
    assert_eq!(sched.quarantined_sessions(), vec![ids[0]]);

    let obs = sched.obs().clone();
    let stats = sched.pool().stats();
    assert_eq!(stats.evictions, obs.evictions.get());
    assert_eq!(stats.restores, obs.restores.get());
    assert!(stats.evictions > 0 && stats.restores > 0);
    assert!(obs.snapshot_bytes_written.get() > 0, "writes must be counted");
    assert!(obs.snapshot_bytes_read.get() > 0, "reads must be counted");
    assert_eq!(
        sched.health().snapshot_failures,
        obs.snapshot_failures.get(),
        "HealthReport reads the same counter"
    );
    assert_eq!(obs.quarantines.get(), 1);
    assert_eq!(obs.unquarantines.get(), 0);

    sched.unquarantine(ids[0]).unwrap();
    assert_eq!(obs.unquarantines.get(), 1);

    let events = obs.drain_events();
    assert!(events.iter().any(|e| matches!(
        e.kind,
        EventKind::Quarantine { session, .. } if session == ids[0]
    )));
    assert!(events.iter().any(|e| matches!(
        e.kind,
        EventKind::Unquarantine { session } if session == ids[0]
    )));
}

/// The kernel-quality gauges carry real values after resample epochs:
/// per-head ESS in (0, m], nonnegative Σ̂ anisotropy, the exact epoch
/// count, and nonzero frozen-epoch bytes — plus one ring event and one
/// counter bump per crossed boundary.
#[test]
fn kernel_quality_gauges_after_resampling() {
    let dir = snapshot_dir("quality");
    let mut pool = SessionPool::with_obs(
        cfg(Precision::F64, 1, 0, dir),
        Box::new(FsStore),
        ObsConfig::full(),
    );
    let id = pool.create_session(7).unwrap();
    let stream = stream_inputs(42);
    for r in 0..N_REQUESTS {
        let heads = slice_heads(&stream, r * CHUNK, (r + 1) * CHUNK);
        pool.session_mut(id).unwrap().step(&heads, CHUNK);
    }
    // L = 32 positions over K = 16 → exactly 2 epochs per head.
    let expected_epochs = (L as u64 / K_EPOCH) * N_HEADS as u64;
    let obs = pool.obs().clone();
    assert_eq!(obs.resample_epochs.get(), expected_epochs);

    let reg = obs.registry();
    let ess = reg.gauge_family_values("rfa_head_ess");
    assert_eq!(ess.len(), N_HEADS, "one ESS gauge per head");
    assert!(
        ess.iter().all(|&v| v > 0.0 && v <= M as f64),
        "ESS must lie in (0, m]: {ess:?}"
    );
    let aniso = reg.gauge_family_values("rfa_head_sigma_anisotropy");
    assert_eq!(aniso.len(), N_HEADS);
    assert!(aniso.iter().all(|&v| v >= 0.0 && v.is_finite()));
    let epochs = reg.gauge_family_values("rfa_head_epochs");
    assert!(
        epochs.iter().all(|&v| v == (L as u64 / K_EPOCH) as f64),
        "epoch gauges must match the boundary count: {epochs:?}"
    );
    let frozen = reg.gauge_family_values("rfa_head_frozen_bytes");
    assert!(
        frozen.iter().all(|&v| v > 0.0),
        "frozen epochs must report resident bytes: {frozen:?}"
    );
    assert!(obs.ess_mean() > 0.0);

    let epoch_events: Vec<Event> = obs
        .drain_events()
        .into_iter()
        .filter(|e| matches!(e.kind, EventKind::ResampleEpoch { .. }))
        .collect();
    assert_eq!(epoch_events.len(), expected_epochs as usize);
    // Events arrive in serial drain order: heads in order per step.
    assert_eq!(
        epoch_events[0].kind,
        EventKind::ResampleEpoch { session: id, head: 0, epoch: 1 }
    );
}
