//! Property tests for the `rfa::serve` subsystem — the three contracts
//! the serving layer is built on:
//!
//! (a) the batch scheduler is a pure transport: per session, its outputs
//!     are bitwise equal to a serial `multi_head_causal_attention` over
//!     the concatenated stream, for every worker count and any arrival
//!     interleaving across sessions;
//! (b) resumability: snapshot → restore → continue produces outputs
//!     bitwise identical (f64) / exact-bits (f32 state) to an
//!     uninterrupted stream;
//! (c) LRU eviction under a tight memory budget changes wall-clock
//!     behavior only — never any session's outputs.
//!
//! Every scheduler property runs under **both** `Precision` variants
//! through shared precision-parameterized helpers
//! ([`check_scheduler_matches_serial`], [`check_lru_eviction_transparent`])
//! — comparisons go through exact f64 widening, which is injective, so
//! equality of widened outputs is bitwise equality of the raw outputs.

use std::path::PathBuf;

use darkformer::linalg::Matrix;
use darkformer::rfa::engine::{
    draw_head_banks, multi_head_causal_attention,
    multi_head_causal_attention32, EngineConfig, Head,
};
use darkformer::rfa::estimators::Sampling;
use darkformer::rfa::gaussian::{
    anisotropic_covariance, MultivariateGaussian,
};
use darkformer::rfa::serve::{
    load_session, save_session, BatchScheduler, Precision, ServeConfig,
    SessionPool, StepRequest,
};
use darkformer::rfa::{FeatureBank, PrfEstimator};
use darkformer::rng::{GaussianExt, Pcg64};

const D: usize = 4;
const M: usize = 16;
const N_HEADS: usize = 2;
const DV: usize = 3;
const CHUNK: usize = 8;
const N_REQUESTS: usize = 4;
const L: usize = CHUNK * N_REQUESTS;

fn iso_est() -> PrfEstimator {
    PrfEstimator::new(D, M, Sampling::Isotropic)
}

fn aware_est() -> PrfEstimator {
    let sigma = anisotropic_covariance(D, 0.7, 0.5, &mut Pcg64::seed(42));
    PrfEstimator::new(
        D,
        M,
        Sampling::DataAware(MultivariateGaussian::new(sigma).unwrap()),
    )
}

/// Fresh per-test snapshot directory (tests run concurrently in one
/// process; stale files from an earlier run must not leak in).
fn snapshot_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("rfa_serve_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn cfg(
    est: PrfEstimator,
    precision: Precision,
    threads: usize,
    memory_budget: usize,
    dir: PathBuf,
) -> ServeConfig {
    ServeConfig {
        est,
        n_heads: N_HEADS,
        dv: DV,
        precision,
        chunk: CHUNK,
        threads,
        memory_budget,
        snapshot_dir: dir,
    }
}

fn rows(l: usize, d: usize, scale: f64, rng: &mut Pcg64) -> Vec<Vec<f64>> {
    (0..l)
        .map(|_| rng.gaussian_vec(d).iter().map(|x| scale * x).collect())
        .collect()
}

/// The full L-position stream for one simulated user, one entry per head.
fn stream_inputs(input_seed: u64) -> Vec<Head> {
    let mut rng = Pcg64::seed(input_seed);
    (0..N_HEADS)
        .map(|_| Head {
            q: rows(L, D, 0.3, &mut rng),
            k: rows(L, D, 0.3, &mut rng),
            v: Matrix::from_rows(&rows(L, DV, 1.0, &mut rng)),
        })
        .collect()
}

/// Rows `[b, e)` of every head — one streaming request segment.
fn slice_heads(heads: &[Head], b: usize, e: usize) -> Vec<Head> {
    heads
        .iter()
        .map(|h| Head {
            q: h.q[b..e].to_vec(),
            k: h.k[b..e].to_vec(),
            v: h.v.row_block(b, e),
        })
        .collect()
}

/// Serial single-tenant reference: same bank seeding as the pool, one
/// monolithic multi-head forward over the whole stream at the requested
/// precision, widened to f64 for comparison (widening is exact, so
/// equality in f64 is bitwise equality of the raw outputs).
fn serial_reference(
    est: &PrfEstimator,
    bank_seed: u64,
    heads: &[Head],
    precision: Precision,
) -> Vec<Matrix> {
    let banks = draw_head_banks(est, N_HEADS, &mut Pcg64::seed(bank_seed));
    let cfg = EngineConfig { chunk: CHUNK, threads: 1 };
    match precision {
        Precision::F64 => multi_head_causal_attention(&banks, heads, &cfg),
        Precision::F32 => {
            multi_head_causal_attention32(&banks, heads, &cfg)
                .into_iter()
                .map(|m| m.to_f64())
                .collect()
        }
    }
}

/// Drive `n_sessions` interleaved streams through a scheduler and return
/// each session's per-head output rows reassembled in stream order.
fn run_scheduled(
    sched: &mut BatchScheduler,
    ids: &[u64],
    streams: &[Vec<Head>],
    interleave_rounds: bool,
) -> Vec<Vec<Matrix>> {
    if interleave_rounds {
        // Round-robin arrival: r0 of every session, then r1, ...
        for r in 0..N_REQUESTS {
            for (id, stream) in ids.iter().zip(streams) {
                let heads = slice_heads(stream, r * CHUNK, (r + 1) * CHUNK);
                sched
                    .submit(StepRequest { session_id: *id, heads })
                    .unwrap();
            }
        }
    } else {
        // Blocked arrival: all of session 0's requests, then session 1's.
        for (id, stream) in ids.iter().zip(streams) {
            for r in 0..N_REQUESTS {
                let heads = slice_heads(stream, r * CHUNK, (r + 1) * CHUNK);
                sched
                    .submit(StepRequest { session_id: *id, heads })
                    .unwrap();
            }
        }
    }
    let mut responses = sched.run_until_idle().unwrap();
    responses.sort_by_key(|r| r.seq);
    let mut per_session: Vec<Vec<Vec<f64>>> =
        vec![vec![Vec::new(); N_HEADS]; ids.len()];
    let mut next_pos: Vec<u64> = vec![0; ids.len()];
    for resp in &responses {
        let s = ids.iter().position(|id| *id == resp.session_id).unwrap();
        // Same-session requests must have applied in arrival order.
        assert_eq!(
            resp.start_position, next_pos[s],
            "session {} saw out-of-order application",
            resp.session_id
        );
        next_pos[s] += resp.outputs[0].rows() as u64;
        for (h, out) in resp.outputs.iter().enumerate() {
            per_session[s][h].extend_from_slice(out.to_f64().data());
        }
    }
    per_session
        .into_iter()
        .map(|heads| {
            heads
                .into_iter()
                .map(|data| Matrix::from_vec(L, DV, data))
                .collect()
        })
        .collect()
}

// ---------------------------------------------------------------- (a)

/// Shared scheduler property at one precision: scheduled outputs are
/// bitwise the serial single-tenant forward, per session, for every
/// worker count × arrival interleaving. Doubles as the thread-count
/// independence check (both worker counts must match the same
/// reference).
fn check_scheduler_matches_serial(precision: Precision, tag: &str) {
    let bank_seeds = [11u64, 22, 33];
    let streams: Vec<Vec<Head>> =
        (0..3).map(|s| stream_inputs(5000 + s)).collect();
    let expected: Vec<Vec<Matrix>> = bank_seeds
        .iter()
        .zip(&streams)
        .map(|(seed, stream)| {
            serial_reference(&iso_est(), *seed, stream, precision)
        })
        .collect();

    for threads in [1usize, 4] {
        for interleave in [true, false] {
            let dir = snapshot_dir(tag);
            let mut pool = SessionPool::new(cfg(
                iso_est(),
                precision,
                threads,
                0,
                dir,
            ));
            let ids: Vec<u64> = bank_seeds
                .iter()
                .map(|s| pool.create_session(*s).unwrap())
                .collect();
            let mut sched = BatchScheduler::new(pool);
            let got = run_scheduled(&mut sched, &ids, &streams, interleave);
            for (s, (got_heads, want_heads)) in
                got.iter().zip(&expected).enumerate()
            {
                for (h, (g, w)) in
                    got_heads.iter().zip(want_heads).enumerate()
                {
                    assert_eq!(
                        g, w,
                        "{precision:?} threads={threads} \
                         interleave={interleave}: session {s} head {h} \
                         diverged from the serial reference"
                    );
                }
            }
        }
    }
}

#[test]
fn scheduler_matches_serial_reference_f64() {
    check_scheduler_matches_serial(Precision::F64, "sched_serial_f64");
}

#[test]
fn scheduler_matches_serial_reference_f32() {
    check_scheduler_matches_serial(Precision::F32, "sched_serial_f32");
}

#[test]
fn deep_single_session_backlog_drains_in_arrival_order() {
    // The per-session FIFO scheduler: a B-deep backlog for one session
    // completes exactly one request per tick, in arrival order, and the
    // reassembled stream still equals the serial reference.
    let stream = stream_inputs(6001);
    let dir = snapshot_dir("fifo_backlog");
    let mut pool =
        SessionPool::new(cfg(iso_est(), Precision::F64, 1, 0, dir));
    let id = pool.create_session(77).unwrap();
    let mut sched = BatchScheduler::new(pool);
    let mut seqs = Vec::new();
    for r in 0..N_REQUESTS {
        let heads = slice_heads(&stream, r * CHUNK, (r + 1) * CHUNK);
        seqs.push(sched.submit(StepRequest { session_id: id, heads }).unwrap());
    }
    assert_eq!(sched.pending_len(), N_REQUESTS);
    let mut responses = Vec::new();
    for done in 0..N_REQUESTS {
        assert_eq!(sched.tick().unwrap(), 1, "one request per tick");
        assert_eq!(sched.pending_len(), N_REQUESTS - done - 1);
        responses.extend(sched.poll_responses());
    }
    assert_eq!(sched.tick().unwrap(), 0, "idle scheduler completes nothing");
    let got_seqs: Vec<u64> = responses.iter().map(|r| r.seq).collect();
    assert_eq!(got_seqs, seqs, "backlog must drain in arrival order");
    let expected = serial_reference(&iso_est(), 77, &stream, Precision::F64);
    let mut heads_data: Vec<Vec<f64>> = vec![Vec::new(); N_HEADS];
    for resp in &responses {
        for (h, out) in resp.outputs.iter().enumerate() {
            heads_data[h].extend_from_slice(out.to_f64().data());
        }
    }
    for (h, want) in expected.iter().enumerate() {
        assert_eq!(
            heads_data[h],
            want.data(),
            "head {h}: FIFO-drained stream diverged from serial"
        );
    }
}

// ---------------------------------------------------------------- (b)

#[test]
fn snapshot_restore_continue_is_bitwise_f64() {
    // Data-aware estimator so the snapshot's Σ tensor path is exercised.
    let stream = stream_inputs(7001);
    let half = L / 2;

    // Uninterrupted stream.
    let dir = snapshot_dir("resume_f64_a");
    let mut pool =
        SessionPool::new(cfg(aware_est(), Precision::F64, 1, 0, dir));
    let id = pool.create_session(99).unwrap();
    let first = pool
        .session_mut(id)
        .unwrap()
        .step(&slice_heads(&stream, 0, half), CHUNK);
    let uninterrupted = pool
        .session_mut(id)
        .unwrap()
        .step(&slice_heads(&stream, half, L), CHUNK);

    // Same stream, evicted to a snapshot (and faulted back) in between.
    let dir = snapshot_dir("resume_f64_b");
    let mut pool =
        SessionPool::new(cfg(aware_est(), Precision::F64, 1, 0, dir));
    let id = pool.create_session(99).unwrap();
    let first_b = pool
        .session_mut(id)
        .unwrap()
        .step(&slice_heads(&stream, 0, half), CHUNK);
    pool.evict(id).unwrap();
    assert_eq!(pool.resident_count(), 0);
    let resumed = pool
        .session_mut(id) // faults in from the snapshot
        .unwrap()
        .step(&slice_heads(&stream, half, L), CHUNK);
    assert_eq!(pool.stats().restores, 1);
    assert_eq!(
        pool.session_mut(id).unwrap().position(),
        L as u64,
        "restored session lost its position counter"
    );

    for h in 0..N_HEADS {
        assert_eq!(
            first[h].as_f64().unwrap(),
            first_b[h].as_f64().unwrap(),
            "head {h}: pre-snapshot outputs differ (rng leak?)"
        );
        assert_eq!(
            uninterrupted[h].as_f64().unwrap(),
            resumed[h].as_f64().unwrap(),
            "head {h}: snapshot→restore→continue diverged from the \
             uninterrupted stream"
        );
    }
}

#[test]
fn snapshot_restore_continue_is_exact_bits_f32() {
    let stream = stream_inputs(7002);
    let half = L / 2;

    let dir = snapshot_dir("resume_f32_a");
    let mut pool =
        SessionPool::new(cfg(iso_est(), Precision::F32, 1, 0, dir));
    let id = pool.create_session(55).unwrap();
    pool.session_mut(id)
        .unwrap()
        .step(&slice_heads(&stream, 0, half), CHUNK);
    let uninterrupted = pool
        .session_mut(id)
        .unwrap()
        .step(&slice_heads(&stream, half, L), CHUNK);

    let dir = snapshot_dir("resume_f32_b");
    let mut pool =
        SessionPool::new(cfg(iso_est(), Precision::F32, 1, 0, dir));
    let id = pool.create_session(55).unwrap();
    pool.session_mut(id)
        .unwrap()
        .step(&slice_heads(&stream, 0, half), CHUNK);
    pool.evict(id).unwrap();
    let resumed = pool
        .session_mut(id)
        .unwrap()
        .step(&slice_heads(&stream, half, L), CHUNK);

    for h in 0..N_HEADS {
        assert_eq!(
            uninterrupted[h].as_f32().unwrap(),
            resumed[h].as_f32().unwrap(),
            "head {h}: f32 restore was not exact-bits"
        );
    }
}

#[test]
fn snapshot_file_round_trips_metadata_and_rejects_corruption() {
    let dir = snapshot_dir("file_meta");
    let mut pool =
        SessionPool::new(cfg(aware_est(), Precision::F64, 1, 0, dir.clone()));
    let id = pool.create_session(1234).unwrap();
    let stream = stream_inputs(7003);
    pool.session_mut(id)
        .unwrap()
        .step(&slice_heads(&stream, 0, CHUNK), CHUNK);

    let path = dir.join("manual.dkft");
    save_session(pool.session_mut(id).unwrap(), &path).unwrap();
    let restored = load_session(&path).unwrap();
    assert_eq!(restored.id(), id);
    assert_eq!(restored.seed(), 1234);
    assert_eq!(restored.position(), CHUNK as u64);
    assert_eq!(restored.precision(), Precision::F64);
    assert_eq!(restored.n_heads(), N_HEADS);
    // Restored banks carry the Σ geometry bit-for-bit.
    let original = pool.session_mut(id).unwrap();
    for (a, b) in
        original.heads().banks().into_iter().zip(restored.heads().banks())
    {
        assert_eq!(a.omegas(), b.omegas());
        assert_eq!(a.weights(), b.weights());
        assert_eq!(a.norm_sigma(), b.norm_sigma());
    }

    // Flip one byte: the load must fail with a described error.
    let mut bytes = std::fs::read(&path).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x40;
    std::fs::write(&path, &bytes).unwrap();
    let err = load_session(&path).unwrap_err();
    assert!(
        format!("{err:#}").contains("CRC"),
        "unexpected error: {err:#}"
    );
}

// ---------------------------------------------------------------- (c)

/// Shared eviction property at one precision: a budget of exactly one
/// session (forcing churn on every cross-session switch) changes no
/// session's outputs.
fn check_lru_eviction_transparent(precision: Precision, tag: &str) {
    let bank_seeds = [301u64, 302, 303];
    let streams: Vec<Vec<Head>> =
        (0..3).map(|s| stream_inputs(8000 + s)).collect();

    // Size the budget to exactly one session so every cross-session
    // switch forces an eviction + restore.
    let one_session_bytes = {
        let dir = snapshot_dir(&format!("{tag}_probe"));
        let mut pool =
            SessionPool::new(cfg(iso_est(), precision, 1, 0, dir));
        let id = pool.create_session(1).unwrap();
        pool.session_mut(id).unwrap().state_bytes()
    };

    let run = |budget: usize, tag: &str| -> Vec<Vec<Matrix>> {
        let dir = snapshot_dir(tag);
        let mut pool = SessionPool::new(cfg(
            iso_est(),
            precision,
            2,
            budget,
            dir,
        ));
        let ids: Vec<u64> = bank_seeds
            .iter()
            .map(|s| pool.create_session(*s).unwrap())
            .collect();
        let mut sched = BatchScheduler::new(pool);
        // Blocked per-tick schedule: drain each session's round before
        // the next session arrives, so the pool keeps switching the
        // resident session under the tight budget.
        let mut outputs: Vec<Vec<Vec<f64>>> =
            vec![vec![Vec::new(); N_HEADS]; ids.len()];
        for r in 0..N_REQUESTS {
            for (s, (id, stream)) in ids.iter().zip(&streams).enumerate() {
                let heads = slice_heads(stream, r * CHUNK, (r + 1) * CHUNK);
                sched
                    .submit(StepRequest { session_id: *id, heads })
                    .unwrap();
                for resp in sched.run_until_idle().unwrap() {
                    for (h, out) in resp.outputs.iter().enumerate() {
                        outputs[s][h].extend_from_slice(out.to_f64().data());
                    }
                }
            }
        }
        let evictions = sched.pool().stats().evictions;
        let restores = sched.pool().stats().restores;
        if budget > 0 {
            assert!(
                evictions >= 3 && restores >= 3,
                "tight budget exercised no churn \
                 (evictions={evictions}, restores={restores})"
            );
        } else {
            assert_eq!(evictions, 0, "unlimited budget must not evict");
        }
        outputs
            .into_iter()
            .map(|heads| {
                heads
                    .into_iter()
                    .map(|data| Matrix::from_vec(L, DV, data))
                    .collect()
            })
            .collect()
    };

    let generous = run(0, &format!("{tag}_generous"));
    let tight = run(one_session_bytes, &format!("{tag}_tight"));
    for s in 0..3 {
        for h in 0..N_HEADS {
            assert_eq!(
                generous[s][h], tight[s][h],
                "{precision:?} session {s} head {h}: eviction churn \
                 changed outputs"
            );
        }
    }
}

#[test]
fn lru_eviction_never_changes_outputs_f64() {
    check_lru_eviction_transparent(Precision::F64, "lru_f64");
}

#[test]
fn lru_eviction_never_changes_outputs_f32() {
    check_lru_eviction_transparent(Precision::F32, "lru_f32");
}

// ------------------------------------------------------------- errors

#[test]
fn submit_validates_session_and_shapes() {
    let dir = snapshot_dir("validate");
    let mut pool =
        SessionPool::new(cfg(iso_est(), Precision::F64, 1, 0, dir));
    let id = pool.create_session(9).unwrap();
    let mut sched = BatchScheduler::new(pool);
    let stream = stream_inputs(9001);

    // Unknown session id.
    let err = sched
        .submit(StepRequest {
            session_id: id + 1000,
            heads: slice_heads(&stream, 0, CHUNK),
        })
        .unwrap_err();
    assert!(format!("{err}").contains("no session"), "got: {err}");

    // Wrong head count.
    let err = sched
        .submit(StepRequest {
            session_id: id,
            heads: slice_heads(&stream, 0, CHUNK)[..1].to_vec(),
        })
        .unwrap_err();
    assert!(format!("{err}").contains("heads"), "got: {err}");

    // Mismatched q/k/v row counts.
    let mut heads = slice_heads(&stream, 0, CHUNK);
    heads[0].q.pop();
    let err = sched
        .submit(StepRequest { session_id: id, heads })
        .unwrap_err();
    assert!(format!("{err}").contains("row counts"), "got: {err}");
}

// ----------------------------------------------- restored-bank physics

#[test]
fn restored_bank_reproduces_feature_maps() {
    // FeatureBank::from_parts must give back the same feature physics —
    // the foundation the snapshot path stands on.
    let est = aware_est();
    let bank = FeatureBank::draw(&est, &mut Pcg64::seed(31337));
    let rebuilt = FeatureBank::from_parts(
        bank.omegas().clone(),
        bank.weights().to_vec(),
        bank.norm_sigma().cloned(),
    );
    let xs = rows(9, D, 0.4, &mut Pcg64::seed(5));
    assert_eq!(bank.feature_matrix(&xs), rebuilt.feature_matrix(&xs));
    assert_eq!(
        bank.feature_matrix32(&xs).data(),
        rebuilt.feature_matrix32(&xs).data()
    );
}
