//! Property tests for the `rfa::serve` subsystem — the three contracts
//! the serving layer is built on:
//!
//! (a) the batch scheduler is a pure transport: per session, its outputs
//!     are bitwise equal to a serial `multi_head_causal_attention` over
//!     the concatenated stream, for every worker count and any arrival
//!     interleaving across sessions;
//! (b) resumability: snapshot → restore → continue produces outputs
//!     bitwise identical (f64) / exact-bits (f32 state) to an
//!     uninterrupted stream;
//! (c) LRU eviction under a tight memory budget changes wall-clock
//!     behavior only — never any session's outputs.
//!
//! Every scheduler property runs under **both** `Precision` variants
//! through shared precision-parameterized helpers
//! ([`check_scheduler_matches_serial`], [`check_lru_eviction_transparent`])
//! — comparisons go through exact f64 widening, which is injective, so
//! equality of widened outputs is bitwise equality of the raw outputs.

use std::path::PathBuf;

use darkformer::linalg::Matrix;
use darkformer::rfa::engine::{
    draw_head_banks, multi_head_causal_attention,
    multi_head_causal_attention32, EngineConfig, Head,
};
use darkformer::rfa::estimators::Sampling;
use darkformer::rfa::gaussian::{
    anisotropic_covariance, MultivariateGaussian,
};
use darkformer::rfa::serve::{
    load_session, save_session, BatchScheduler, CompactionConfig,
    Precision, ResampleConfig, ServeConfig, SessionHeads, SessionPool,
    StepRequest,
};
use darkformer::rfa::{FeatureBank, PrfEstimator};
use darkformer::rng::{GaussianExt, Pcg64};

const D: usize = 4;
const M: usize = 16;
const N_HEADS: usize = 2;
const DV: usize = 3;
const CHUNK: usize = 8;
const N_REQUESTS: usize = 4;
const L: usize = CHUNK * N_REQUESTS;

fn iso_est() -> PrfEstimator {
    PrfEstimator::new(D, M, Sampling::Isotropic)
}

fn aware_est() -> PrfEstimator {
    let sigma = anisotropic_covariance(D, 0.7, 0.5, &mut Pcg64::seed(42));
    PrfEstimator::new(
        D,
        M,
        Sampling::DataAware(MultivariateGaussian::new(sigma).unwrap()),
    )
}

/// Fresh per-test snapshot directory (tests run concurrently in one
/// process; stale files from an earlier run must not leak in).
fn snapshot_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("rfa_serve_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn cfg(
    est: PrfEstimator,
    precision: Precision,
    threads: usize,
    memory_budget: usize,
    dir: PathBuf,
) -> ServeConfig {
    ServeConfig {
        est,
        n_heads: N_HEADS,
        dv: DV,
        precision,
        chunk: CHUNK,
        threads,
        memory_budget,
        snapshot_dir: dir,
        resample: None,
    }
}

fn cfg_resample(
    est: PrfEstimator,
    precision: Precision,
    threads: usize,
    memory_budget: usize,
    dir: PathBuf,
    rc: ResampleConfig,
) -> ServeConfig {
    ServeConfig {
        resample: Some(rc),
        ..cfg(est, precision, threads, memory_budget, dir)
    }
}

fn rows(l: usize, d: usize, scale: f64, rng: &mut Pcg64) -> Vec<Vec<f64>> {
    (0..l)
        .map(|_| rng.gaussian_vec(d).iter().map(|x| scale * x).collect())
        .collect()
}

/// The full L-position stream for one simulated user, one entry per head.
fn stream_inputs(input_seed: u64) -> Vec<Head> {
    let mut rng = Pcg64::seed(input_seed);
    (0..N_HEADS)
        .map(|_| Head {
            q: rows(L, D, 0.3, &mut rng),
            k: rows(L, D, 0.3, &mut rng),
            v: Matrix::from_rows(&rows(L, DV, 1.0, &mut rng)),
        })
        .collect()
}

/// Rows `[b, e)` of every head — one streaming request segment.
fn slice_heads(heads: &[Head], b: usize, e: usize) -> Vec<Head> {
    heads
        .iter()
        .map(|h| Head {
            q: h.q[b..e].to_vec(),
            k: h.k[b..e].to_vec(),
            v: h.v.row_block(b, e),
        })
        .collect()
}

/// Serial single-tenant reference: same bank seeding as the pool, one
/// monolithic multi-head forward over the whole stream at the requested
/// precision, widened to f64 for comparison (widening is exact, so
/// equality in f64 is bitwise equality of the raw outputs).
fn serial_reference(
    est: &PrfEstimator,
    bank_seed: u64,
    heads: &[Head],
    precision: Precision,
) -> Vec<Matrix> {
    let banks = draw_head_banks(est, N_HEADS, &mut Pcg64::seed(bank_seed));
    let cfg = EngineConfig { chunk: CHUNK, threads: 1 };
    match precision {
        Precision::F64 => multi_head_causal_attention(&banks, heads, &cfg),
        Precision::F32 => {
            multi_head_causal_attention32(&banks, heads, &cfg)
                .into_iter()
                .map(|m| m.to_f64())
                .collect()
        }
    }
}

/// Drive `n_sessions` interleaved streams through a scheduler and return
/// each session's per-head output rows reassembled in stream order.
fn run_scheduled(
    sched: &mut BatchScheduler,
    ids: &[u64],
    streams: &[Vec<Head>],
    interleave_rounds: bool,
) -> Vec<Vec<Matrix>> {
    if interleave_rounds {
        // Round-robin arrival: r0 of every session, then r1, ...
        for r in 0..N_REQUESTS {
            for (id, stream) in ids.iter().zip(streams) {
                let heads = slice_heads(stream, r * CHUNK, (r + 1) * CHUNK);
                sched
                    .submit(StepRequest { session_id: *id, heads })
                    .unwrap();
            }
        }
    } else {
        // Blocked arrival: all of session 0's requests, then session 1's.
        for (id, stream) in ids.iter().zip(streams) {
            for r in 0..N_REQUESTS {
                let heads = slice_heads(stream, r * CHUNK, (r + 1) * CHUNK);
                sched
                    .submit(StepRequest { session_id: *id, heads })
                    .unwrap();
            }
        }
    }
    let responses = sched.run_until_idle().into_result().unwrap();
    reassemble_streams(responses, ids)
}

/// Reassemble drained responses into per-session, per-head output
/// matrices in stream order, asserting in-order application.
fn reassemble_streams(
    mut responses: Vec<darkformer::rfa::serve::StepResponse>,
    ids: &[u64],
) -> Vec<Vec<Matrix>> {
    responses.sort_by_key(|r| r.seq);
    let mut per_session: Vec<Vec<Vec<f64>>> =
        vec![vec![Vec::new(); N_HEADS]; ids.len()];
    let mut next_pos: Vec<u64> = vec![0; ids.len()];
    for resp in &responses {
        let s = ids.iter().position(|id| *id == resp.session_id).unwrap();
        // Same-session requests must have applied in arrival order.
        assert_eq!(
            resp.start_position, next_pos[s],
            "session {} saw out-of-order application",
            resp.session_id
        );
        next_pos[s] += resp.outputs[0].rows() as u64;
        for (h, out) in resp.outputs.iter().enumerate() {
            per_session[s][h].extend_from_slice(out.to_f64().data());
        }
    }
    per_session
        .into_iter()
        .map(|heads| {
            heads
                .into_iter()
                .map(|data| Matrix::from_vec(L, DV, data))
                .collect()
        })
        .collect()
}

/// Resident bytes of one fresh static-bank session at `precision` — the
/// probe the budget-churn tests size their pools with.
fn one_session_bytes(precision: Precision, tag: &str) -> usize {
    let dir = snapshot_dir(tag);
    let mut pool = SessionPool::new(cfg(iso_est(), precision, 1, 0, dir));
    let id = pool.create_session(1).unwrap();
    pool.session_mut(id).unwrap().state_bytes()
}

// ---------------------------------------------------------------- (a)

/// Shared scheduler property at one precision: scheduled outputs are
/// bitwise the serial single-tenant forward, per session, for every
/// worker count × arrival interleaving. Doubles as the thread-count
/// independence check (both worker counts must match the same
/// reference).
fn check_scheduler_matches_serial(precision: Precision, tag: &str) {
    let bank_seeds = [11u64, 22, 33];
    let streams: Vec<Vec<Head>> =
        (0..3).map(|s| stream_inputs(5000 + s)).collect();
    let expected: Vec<Vec<Matrix>> = bank_seeds
        .iter()
        .zip(&streams)
        .map(|(seed, stream)| {
            serial_reference(&iso_est(), *seed, stream, precision)
        })
        .collect();

    for threads in [1usize, 4] {
        for interleave in [true, false] {
            let dir = snapshot_dir(tag);
            let mut pool = SessionPool::new(cfg(
                iso_est(),
                precision,
                threads,
                0,
                dir,
            ));
            let ids: Vec<u64> = bank_seeds
                .iter()
                .map(|s| pool.create_session(*s).unwrap())
                .collect();
            let mut sched = BatchScheduler::new(pool);
            let got = run_scheduled(&mut sched, &ids, &streams, interleave);
            for (s, (got_heads, want_heads)) in
                got.iter().zip(&expected).enumerate()
            {
                for (h, (g, w)) in
                    got_heads.iter().zip(want_heads).enumerate()
                {
                    assert_eq!(
                        g, w,
                        "{precision:?} threads={threads} \
                         interleave={interleave}: session {s} head {h} \
                         diverged from the serial reference"
                    );
                }
            }
        }
    }
}

#[test]
fn scheduler_matches_serial_reference_f64() {
    check_scheduler_matches_serial(Precision::F64, "sched_serial_f64");
}

#[test]
fn scheduler_matches_serial_reference_f32() {
    check_scheduler_matches_serial(Precision::F32, "sched_serial_f32");
}

#[test]
fn deep_single_session_backlog_drains_in_arrival_order() {
    // The per-session FIFO scheduler: a B-deep backlog for one session
    // completes exactly one request per tick, in arrival order, and the
    // reassembled stream still equals the serial reference.
    let stream = stream_inputs(6001);
    let dir = snapshot_dir("fifo_backlog");
    let mut pool =
        SessionPool::new(cfg(iso_est(), Precision::F64, 1, 0, dir));
    let id = pool.create_session(77).unwrap();
    let mut sched = BatchScheduler::new(pool);
    let mut seqs = Vec::new();
    for r in 0..N_REQUESTS {
        let heads = slice_heads(&stream, r * CHUNK, (r + 1) * CHUNK);
        seqs.push(sched.submit(StepRequest { session_id: id, heads }).unwrap());
    }
    assert_eq!(sched.pending_len(), N_REQUESTS);
    let mut responses = Vec::new();
    for done in 0..N_REQUESTS {
        assert_eq!(sched.tick().unwrap(), 1, "one request per tick");
        assert_eq!(sched.pending_len(), N_REQUESTS - done - 1);
        responses.extend(sched.poll_responses());
    }
    assert_eq!(sched.tick().unwrap(), 0, "idle scheduler completes nothing");
    let got_seqs: Vec<u64> = responses.iter().map(|r| r.seq).collect();
    assert_eq!(got_seqs, seqs, "backlog must drain in arrival order");
    let expected = serial_reference(&iso_est(), 77, &stream, Precision::F64);
    let mut heads_data: Vec<Vec<f64>> = vec![Vec::new(); N_HEADS];
    for resp in &responses {
        for (h, out) in resp.outputs.iter().enumerate() {
            heads_data[h].extend_from_slice(out.to_f64().data());
        }
    }
    for (h, want) in expected.iter().enumerate() {
        assert_eq!(
            heads_data[h],
            want.data(),
            "head {h}: FIFO-drained stream diverged from serial"
        );
    }
}

// ---------------------------------------------------------------- (b)

#[test]
fn snapshot_restore_continue_is_bitwise_f64() {
    // Data-aware estimator so the snapshot's Σ tensor path is exercised.
    let stream = stream_inputs(7001);
    let half = L / 2;

    // Uninterrupted stream.
    let dir = snapshot_dir("resume_f64_a");
    let mut pool =
        SessionPool::new(cfg(aware_est(), Precision::F64, 1, 0, dir));
    let id = pool.create_session(99).unwrap();
    let first = pool
        .session_mut(id)
        .unwrap()
        .step(&slice_heads(&stream, 0, half), CHUNK);
    let uninterrupted = pool
        .session_mut(id)
        .unwrap()
        .step(&slice_heads(&stream, half, L), CHUNK);

    // Same stream, evicted to a snapshot (and faulted back) in between.
    let dir = snapshot_dir("resume_f64_b");
    let mut pool =
        SessionPool::new(cfg(aware_est(), Precision::F64, 1, 0, dir));
    let id = pool.create_session(99).unwrap();
    let first_b = pool
        .session_mut(id)
        .unwrap()
        .step(&slice_heads(&stream, 0, half), CHUNK);
    pool.evict(id).unwrap();
    assert_eq!(pool.resident_count(), 0);
    let resumed = pool
        .session_mut(id) // faults in from the snapshot
        .unwrap()
        .step(&slice_heads(&stream, half, L), CHUNK);
    assert_eq!(pool.stats().restores, 1);
    assert_eq!(
        pool.session_mut(id).unwrap().position(),
        L as u64,
        "restored session lost its position counter"
    );

    for h in 0..N_HEADS {
        assert_eq!(
            first[h].as_f64().unwrap(),
            first_b[h].as_f64().unwrap(),
            "head {h}: pre-snapshot outputs differ (rng leak?)"
        );
        assert_eq!(
            uninterrupted[h].as_f64().unwrap(),
            resumed[h].as_f64().unwrap(),
            "head {h}: snapshot→restore→continue diverged from the \
             uninterrupted stream"
        );
    }
}

#[test]
fn snapshot_restore_continue_is_exact_bits_f32() {
    let stream = stream_inputs(7002);
    let half = L / 2;

    let dir = snapshot_dir("resume_f32_a");
    let mut pool =
        SessionPool::new(cfg(iso_est(), Precision::F32, 1, 0, dir));
    let id = pool.create_session(55).unwrap();
    pool.session_mut(id)
        .unwrap()
        .step(&slice_heads(&stream, 0, half), CHUNK);
    let uninterrupted = pool
        .session_mut(id)
        .unwrap()
        .step(&slice_heads(&stream, half, L), CHUNK);

    let dir = snapshot_dir("resume_f32_b");
    let mut pool =
        SessionPool::new(cfg(iso_est(), Precision::F32, 1, 0, dir));
    let id = pool.create_session(55).unwrap();
    pool.session_mut(id)
        .unwrap()
        .step(&slice_heads(&stream, 0, half), CHUNK);
    pool.evict(id).unwrap();
    let resumed = pool
        .session_mut(id)
        .unwrap()
        .step(&slice_heads(&stream, half, L), CHUNK);

    for h in 0..N_HEADS {
        assert_eq!(
            uninterrupted[h].as_f32().unwrap(),
            resumed[h].as_f32().unwrap(),
            "head {h}: f32 restore was not exact-bits"
        );
    }
}

#[test]
fn snapshot_file_round_trips_metadata_and_rejects_corruption() {
    let dir = snapshot_dir("file_meta");
    let mut pool =
        SessionPool::new(cfg(aware_est(), Precision::F64, 1, 0, dir.clone()));
    let id = pool.create_session(1234).unwrap();
    let stream = stream_inputs(7003);
    pool.session_mut(id)
        .unwrap()
        .step(&slice_heads(&stream, 0, CHUNK), CHUNK);

    let path = dir.join("manual.dkft");
    save_session(pool.session_mut(id).unwrap(), &path).unwrap();
    let restored = load_session(&path).unwrap();
    assert_eq!(restored.id(), id);
    assert_eq!(restored.seed(), 1234);
    assert_eq!(restored.position(), CHUNK as u64);
    assert_eq!(restored.precision(), Precision::F64);
    assert_eq!(restored.n_heads(), N_HEADS);
    // Restored banks carry the Σ geometry bit-for-bit.
    let original = pool.session_mut(id).unwrap();
    for (a, b) in
        original.heads().banks().into_iter().zip(restored.heads().banks())
    {
        assert_eq!(a.omegas(), b.omegas());
        assert_eq!(a.weights(), b.weights());
        assert_eq!(a.norm_sigma(), b.norm_sigma());
    }

    // Flip one byte: the load must fail with a described error.
    let mut bytes = std::fs::read(&path).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x40;
    std::fs::write(&path, &bytes).unwrap();
    let err = load_session(&path).unwrap_err();
    assert!(
        format!("{err:#}").contains("CRC"),
        "unexpected error: {err:#}"
    );
}

// ---------------------------------------------------------------- (c)

/// Shared eviction property at one precision: a budget of exactly one
/// session (forcing churn on every cross-session switch) changes no
/// session's outputs.
fn check_lru_eviction_transparent(precision: Precision, tag: &str) {
    let bank_seeds = [301u64, 302, 303];
    let streams: Vec<Vec<Head>> =
        (0..3).map(|s| stream_inputs(8000 + s)).collect();

    // Size the budget to exactly one session so every cross-session
    // switch forces an eviction + restore.
    let one_session_bytes = {
        let dir = snapshot_dir(&format!("{tag}_probe"));
        let mut pool =
            SessionPool::new(cfg(iso_est(), precision, 1, 0, dir));
        let id = pool.create_session(1).unwrap();
        pool.session_mut(id).unwrap().state_bytes()
    };

    let run = |budget: usize, tag: &str| -> Vec<Vec<Matrix>> {
        let dir = snapshot_dir(tag);
        let mut pool = SessionPool::new(cfg(
            iso_est(),
            precision,
            2,
            budget,
            dir,
        ));
        let ids: Vec<u64> = bank_seeds
            .iter()
            .map(|s| pool.create_session(*s).unwrap())
            .collect();
        let mut sched = BatchScheduler::new(pool);
        // Blocked per-tick schedule: drain each session's round before
        // the next session arrives, so the pool keeps switching the
        // resident session under the tight budget.
        let mut outputs: Vec<Vec<Vec<f64>>> =
            vec![vec![Vec::new(); N_HEADS]; ids.len()];
        for r in 0..N_REQUESTS {
            for (s, (id, stream)) in ids.iter().zip(&streams).enumerate() {
                let heads = slice_heads(stream, r * CHUNK, (r + 1) * CHUNK);
                sched
                    .submit(StepRequest { session_id: *id, heads })
                    .unwrap();
                for resp in sched.run_until_idle().into_result().unwrap() {
                    for (h, out) in resp.outputs.iter().enumerate() {
                        outputs[s][h].extend_from_slice(out.to_f64().data());
                    }
                }
            }
        }
        let evictions = sched.pool().stats().evictions;
        let restores = sched.pool().stats().restores;
        if budget > 0 {
            assert!(
                evictions >= 3 && restores >= 3,
                "tight budget exercised no churn \
                 (evictions={evictions}, restores={restores})"
            );
        } else {
            assert_eq!(evictions, 0, "unlimited budget must not evict");
        }
        outputs
            .into_iter()
            .map(|heads| {
                heads
                    .into_iter()
                    .map(|data| Matrix::from_vec(L, DV, data))
                    .collect()
            })
            .collect()
    };

    let generous = run(0, &format!("{tag}_generous"));
    let tight = run(one_session_bytes, &format!("{tag}_tight"));
    for s in 0..3 {
        for h in 0..N_HEADS {
            assert_eq!(
                generous[s][h], tight[s][h],
                "{precision:?} session {s} head {h}: eviction churn \
                 changed outputs"
            );
        }
    }
}

#[test]
fn lru_eviction_never_changes_outputs_f64() {
    check_lru_eviction_transparent(Precision::F64, "lru_f64");
}

#[test]
fn lru_eviction_never_changes_outputs_f32() {
    check_lru_eviction_transparent(Precision::F32, "lru_f32");
}

// ------------------------------------------------------------- errors

#[test]
fn submit_validates_session_and_shapes() {
    let dir = snapshot_dir("validate");
    let mut pool =
        SessionPool::new(cfg(iso_est(), Precision::F64, 1, 0, dir));
    let id = pool.create_session(9).unwrap();
    let mut sched = BatchScheduler::new(pool);
    let stream = stream_inputs(9001);

    // Unknown session id.
    let err = sched
        .submit(StepRequest {
            session_id: id + 1000,
            heads: slice_heads(&stream, 0, CHUNK),
        })
        .unwrap_err();
    assert!(format!("{err}").contains("no session"), "got: {err}");

    // Wrong head count.
    let err = sched
        .submit(StepRequest {
            session_id: id,
            heads: slice_heads(&stream, 0, CHUNK)[..1].to_vec(),
        })
        .unwrap_err();
    assert!(format!("{err}").contains("heads"), "got: {err}");

    // Mismatched q/k/v row counts.
    let mut heads = slice_heads(&stream, 0, CHUNK);
    heads[0].q.pop();
    let err = sched
        .submit(StepRequest { session_id: id, heads })
        .unwrap_err();
    assert!(format!("{err}").contains("row counts"), "got: {err}");
}

// ----------------------------------------------- restored-bank physics

#[test]
fn restored_bank_reproduces_feature_maps() {
    // FeatureBank::from_parts must give back the same feature physics —
    // the foundation the snapshot path stands on.
    let est = aware_est();
    let bank = FeatureBank::draw(&est, &mut Pcg64::seed(31337));
    let rebuilt = FeatureBank::from_parts(
        bank.omegas().clone(),
        bank.weights().to_vec(),
        bank.norm_sigma().cloned(),
    );
    let xs = rows(9, D, 0.4, &mut Pcg64::seed(5));
    assert_eq!(bank.feature_matrix(&xs), rebuilt.feature_matrix(&xs));
    assert_eq!(
        bank.feature_matrix32(&xs).data(),
        rebuilt.feature_matrix32(&xs).data()
    );
}

// ------------------------------------------- (d) online bank resampling

#[test]
fn resample_epochs_advance_and_redraw_data_aware_banks() {
    // K = CHUNK → every request crosses exactly one epoch boundary.
    let rc = ResampleConfig {
        epoch_positions: CHUNK as u64,
        max_epochs: 2,
        shrinkage: 0.05,
        compaction: None,
    };
    let dir = snapshot_dir("resample_epochs");
    let mut pool = SessionPool::new(cfg_resample(
        iso_est(),
        Precision::F64,
        1,
        0,
        dir,
        rc,
    ));
    let id = pool.create_session(5150).unwrap();
    let stream = stream_inputs(9200);

    // Epoch 0 banks are the static draw for the configured estimator:
    // isotropic here, so no Σ geometry yet.
    let initial_omegas: Vec<Matrix> = {
        let session = pool.session_mut(id).unwrap();
        assert_eq!(session.head_epochs(), vec![0; N_HEADS]);
        let banks = session.heads().banks();
        assert!(banks.iter().all(|b| b.norm_sigma().is_none()));
        banks.into_iter().map(|b| b.omegas().clone()).collect()
    };

    for r in 0..N_REQUESTS {
        pool.session_mut(id)
            .unwrap()
            .step(&slice_heads(&stream, r * CHUNK, (r + 1) * CHUNK), CHUNK);
    }

    let session = pool.session_mut(id).unwrap();
    assert_eq!(
        session.head_epochs(),
        vec![N_REQUESTS as u64; N_HEADS],
        "one boundary per request at K = CHUNK"
    );
    // Every live bank is now a data-aware redraw against the streamed Σ̂.
    let banks = session.heads().banks();
    for (h, bank) in banks.iter().enumerate() {
        assert!(
            bank.norm_sigma().is_some(),
            "head {h}: resampled bank is not data-aware"
        );
        assert_ne!(
            bank.omegas(),
            &initial_omegas[h],
            "head {h}: bank unchanged after {N_REQUESTS} resamples"
        );
    }
    // Distinct heads must draw distinct banks at the same epoch (the
    // redraw rng streams by head).
    assert_ne!(banks[0].omegas(), banks[1].omegas());
    // Retention: 4 freezes against a cap of 2, and the moment
    // accumulator saw every key of the stream.
    match session.heads() {
        SessionHeads::F64(slots) => {
            for (h, slot) in slots.iter().enumerate() {
                let online = slot.online().unwrap();
                assert_eq!(
                    online.frozen_len(),
                    2,
                    "head {h}: retained-epoch cap not enforced"
                );
                assert_eq!(online.count(), L as u64);
                assert_eq!(online.epoch(), N_REQUESTS as u64);
            }
        }
        SessionHeads::F32(_) => unreachable!("pool built at F64"),
    }
}

#[test]
fn online_resampling_is_bitwise_noop_before_first_boundary() {
    // K > L: the stream never reaches a boundary, so the online path —
    // moment tracking and all — must reproduce the static serial
    // reference bit for bit at both precisions.
    for (precision, tag) in
        [(Precision::F64, "noop_f64"), (Precision::F32, "noop_f32")]
    {
        let rc = ResampleConfig {
            epoch_positions: (L + 1) as u64,
            max_epochs: 3,
            shrinkage: 0.1,
            compaction: None,
        };
        let stream = stream_inputs(9300);
        let expected = serial_reference(&iso_est(), 808, &stream, precision);
        let dir = snapshot_dir(tag);
        let mut pool = SessionPool::new(cfg_resample(
            iso_est(),
            precision,
            1,
            0,
            dir,
            rc,
        ));
        let ids = vec![pool.create_session(808).unwrap()];
        let mut sched = BatchScheduler::new(pool);
        let got = run_scheduled(
            &mut sched,
            &ids,
            std::slice::from_ref(&stream),
            false,
        );
        for h in 0..N_HEADS {
            assert_eq!(
                got[0][h], expected[h],
                "{precision:?} head {h}: online path changed bits before \
                 its first boundary"
            );
        }
    }
}

/// The tentpole acceptance property at one precision: with boundaries
/// at 12 and 24 (mid-request and exactly on a request edge),
/// evict→restore→continue across resample epochs is bitwise identical
/// to the uninterrupted stream, and the scheduler transport reproduces
/// the same bits at worker counts {1, 4}.
fn check_online_resume(precision: Precision, max_epochs: usize, tag: &str) {
    let rc = ResampleConfig {
        epoch_positions: 12,
        max_epochs,
        shrinkage: 0.05,
        compaction: None,
    };
    let stream = stream_inputs(9100);
    let seed = 4242u64;

    // Uninterrupted reference: direct pool, serial segment steps.
    let dir = snapshot_dir(&format!("{tag}_ref"));
    let mut pool = SessionPool::new(cfg_resample(
        iso_est(),
        precision,
        1,
        0,
        dir,
        rc.clone(),
    ));
    let id = pool.create_session(seed).unwrap();
    let mut expected: Vec<Vec<f64>> = vec![Vec::new(); N_HEADS];
    for r in 0..N_REQUESTS {
        let outs = pool
            .session_mut(id)
            .unwrap()
            .step(&slice_heads(&stream, r * CHUNK, (r + 1) * CHUNK), CHUNK);
        for (h, out) in outs.iter().enumerate() {
            expected[h].extend_from_slice(out.to_f64().data());
        }
    }
    assert_eq!(
        pool.session_mut(id).unwrap().head_epochs(),
        vec![2; N_HEADS],
        "L = 32 with K = 12 must complete two epochs"
    );

    // Same stream, evicted to a snapshot after every segment: request 1
    // crosses the first boundary (position 12), request 2 ends exactly
    // on the second (position 24) — both frozen triples, the moment
    // accumulator, and the live bank must round-trip exact-bits.
    let dir = snapshot_dir(&format!("{tag}_resume"));
    let mut pool = SessionPool::new(cfg_resample(
        iso_est(),
        precision,
        1,
        0,
        dir,
        rc.clone(),
    ));
    let id = pool.create_session(seed).unwrap();
    let mut resumed: Vec<Vec<f64>> = vec![Vec::new(); N_HEADS];
    for r in 0..N_REQUESTS {
        let outs = pool
            .session_mut(id)
            .unwrap()
            .step(&slice_heads(&stream, r * CHUNK, (r + 1) * CHUNK), CHUNK);
        for (h, out) in outs.iter().enumerate() {
            resumed[h].extend_from_slice(out.to_f64().data());
        }
        if r + 1 < N_REQUESTS {
            pool.evict(id).unwrap();
        }
    }
    assert_eq!(pool.stats().restores, (N_REQUESTS - 1) as u64);
    for h in 0..N_HEADS {
        assert_eq!(
            expected[h], resumed[h],
            "{precision:?} max_epochs={max_epochs} head {h}: \
             evict→restore across a resample epoch changed bits"
        );
    }

    // Scheduler transport must reproduce the same bits at {1, 4} workers.
    for threads in [1usize, 4] {
        let dir = snapshot_dir(&format!("{tag}_sched{threads}"));
        let mut pool = SessionPool::new(cfg_resample(
            iso_est(),
            precision,
            threads,
            0,
            dir,
            rc.clone(),
        ));
        let ids = vec![pool.create_session(seed).unwrap()];
        let mut sched = BatchScheduler::new(pool);
        let got = run_scheduled(
            &mut sched,
            &ids,
            std::slice::from_ref(&stream),
            false,
        );
        for h in 0..N_HEADS {
            assert_eq!(
                got[0][h].data(),
                expected[h].as_slice(),
                "{precision:?} threads={threads} head {h}: scheduled \
                 online stream diverged"
            );
        }
    }
}

#[test]
fn online_evict_restore_bitwise_across_epochs_f64() {
    check_online_resume(Precision::F64, 8, "online_resume_f64");
}

#[test]
fn online_evict_restore_bitwise_across_epochs_f32() {
    // max_epochs = 1 exercises the frozen-epoch drop at the second
    // boundary — the sliding-window path must also restore exact-bits.
    check_online_resume(Precision::F32, 1, "online_resume_f32");
}

// ------------------------------------- (d2) frozen-epoch compaction

/// Drive one session's full stream through a direct pool under `rc`,
/// returning (per-head output rows, per-head `(frozen_len, compactions)`
/// probes, resident state bytes).
fn run_resampled(
    rc: ResampleConfig,
    precision: Precision,
    tag: &str,
) -> (Vec<Vec<f64>>, Vec<(usize, u64)>, usize) {
    let dir = snapshot_dir(tag);
    let mut pool = SessionPool::new(cfg_resample(
        iso_est(),
        precision,
        1,
        0,
        dir,
        rc,
    ));
    let id = pool.create_session(7070).unwrap();
    let stream = stream_inputs(9600);
    let mut outs: Vec<Vec<f64>> = vec![Vec::new(); N_HEADS];
    for r in 0..N_REQUESTS {
        let step = pool
            .session_mut(id)
            .unwrap()
            .step(&slice_heads(&stream, r * CHUNK, (r + 1) * CHUNK), CHUNK);
        for (h, out) in step.iter().enumerate() {
            outs[h].extend_from_slice(out.to_f64().data());
        }
    }
    let session = pool.session_mut(id).unwrap();
    fn probe<T: darkformer::linalg::Scalar>(
        slots: &[darkformer::rfa::serve::HeadSlot<T>],
    ) -> Vec<(usize, u64)> {
        slots
            .iter()
            .map(|s| {
                let o = s.online().unwrap();
                (o.frozen_len(), o.compactions())
            })
            .collect()
    }
    let probes = match session.heads() {
        SessionHeads::F64(slots) => probe(slots),
        SessionHeads::F32(slots) => probe(slots),
    };
    let bytes = session.state_bytes();
    (outs, probes, bytes)
}

#[test]
fn compaction_bounds_frozen_epochs_and_off_is_bitwise_noop() {
    // K = CHUNK: every request crosses one boundary → 4 frozen epochs
    // without compaction (cap 8 never binds).
    let rc_off = ResampleConfig {
        epoch_positions: CHUNK as u64,
        max_epochs: 8,
        shrinkage: 0.05,
        compaction: None,
    };
    let mut rc_wide = rc_off.clone();
    rc_wide.compaction = Some(CompactionConfig::keep(8));
    let mut rc_on = rc_off.clone();
    rc_on.compaction =
        Some(CompactionConfig { window: 1, probes: 16, ridge: 1e-8 });

    for precision in [Precision::F64, Precision::F32] {
        let (out_off, probes_off, bytes_off) =
            run_resampled(rc_off.clone(), precision, "compact_off");
        let (out_wide, probes_wide, _) =
            run_resampled(rc_wide.clone(), precision, "compact_wide");
        let (_, probes_on, bytes_on) =
            run_resampled(rc_on.clone(), precision, "compact_on");

        // A window the deque never exceeds is a structural no-op: same
        // retained epochs, zero merges, and bitwise-identical outputs.
        assert_eq!(
            out_off, out_wide,
            "{precision:?}: an untriggered compaction window changed bits"
        );
        assert_eq!(probes_off, vec![(N_REQUESTS, 0); N_HEADS]);
        assert_eq!(probes_wide, probes_off);

        // window = 1 holds exactly one frozen epoch per head, merging
        // the other N_REQUESTS - 1 — and the resident state shrinks.
        assert_eq!(
            probes_on,
            vec![(1, (N_REQUESTS - 1) as u64); N_HEADS],
            "{precision:?}: compaction window not enforced"
        );
        assert!(
            bytes_on < bytes_off,
            "{precision:?}: compaction must shrink resident bytes \
             ({bytes_on} vs {bytes_off})"
        );
    }
}

/// Snapshot-v3 acceptance half: with boundaries at 12/24 (mid-request
/// and on a request edge) *and* a window-1 compaction merge at the
/// second boundary, evict→restore→continue is bitwise identical to the
/// uninterrupted stream, and the scheduler reproduces the same bits at
/// worker counts {1, 4}.
fn check_compaction_resume(precision: Precision, tag: &str) {
    let rc = ResampleConfig {
        epoch_positions: 12,
        max_epochs: 8,
        shrinkage: 0.05,
        compaction: Some(CompactionConfig {
            window: 1,
            probes: 16,
            ridge: 1e-8,
        }),
    };
    let stream = stream_inputs(9100);
    let seed = 4242u64;

    // Uninterrupted reference.
    let dir = snapshot_dir(&format!("{tag}_ref"));
    let mut pool = SessionPool::new(cfg_resample(
        iso_est(),
        precision,
        1,
        0,
        dir,
        rc.clone(),
    ));
    let id = pool.create_session(seed).unwrap();
    let mut expected: Vec<Vec<f64>> = vec![Vec::new(); N_HEADS];
    for r in 0..N_REQUESTS {
        let outs = pool
            .session_mut(id)
            .unwrap()
            .step(&slice_heads(&stream, r * CHUNK, (r + 1) * CHUNK), CHUNK);
        for (h, out) in outs.iter().enumerate() {
            expected[h].extend_from_slice(out.to_f64().data());
        }
    }
    // Two boundaries crossed; window 1 forced one merge per head.
    match pool.session_mut(id).unwrap().heads() {
        SessionHeads::F64(slots) => {
            for slot in slots {
                let o = slot.online().unwrap();
                assert_eq!((o.frozen_len(), o.compactions()), (1, 1));
                assert!(o.chol_factor().is_some(), "factor must be live");
            }
        }
        SessionHeads::F32(slots) => {
            for slot in slots {
                let o = slot.online().unwrap();
                assert_eq!((o.frozen_len(), o.compactions()), (1, 1));
                assert!(o.chol_factor().is_some(), "factor must be live");
            }
        }
    }

    // Same stream, evicted after every segment: the maintained factor,
    // its counters and the merged frozen state all cross the v3
    // snapshot — any loss would diverge the later segments.
    let dir = snapshot_dir(&format!("{tag}_resume"));
    let mut pool = SessionPool::new(cfg_resample(
        iso_est(),
        precision,
        1,
        0,
        dir,
        rc.clone(),
    ));
    let id = pool.create_session(seed).unwrap();
    let mut resumed: Vec<Vec<f64>> = vec![Vec::new(); N_HEADS];
    for r in 0..N_REQUESTS {
        let outs = pool
            .session_mut(id)
            .unwrap()
            .step(&slice_heads(&stream, r * CHUNK, (r + 1) * CHUNK), CHUNK);
        for (h, out) in outs.iter().enumerate() {
            resumed[h].extend_from_slice(out.to_f64().data());
        }
        if r + 1 < N_REQUESTS {
            pool.evict(id).unwrap();
        }
    }
    for h in 0..N_HEADS {
        assert_eq!(
            expected[h], resumed[h],
            "{precision:?} head {h}: evict→restore across a resample + \
             compaction boundary changed bits"
        );
    }

    // Scheduler transport at {1, 4} workers.
    for threads in [1usize, 4] {
        let dir = snapshot_dir(&format!("{tag}_sched{threads}"));
        let mut pool = SessionPool::new(cfg_resample(
            iso_est(),
            precision,
            threads,
            0,
            dir,
            rc.clone(),
        ));
        let ids = vec![pool.create_session(seed).unwrap()];
        let mut sched = BatchScheduler::new(pool);
        let got = run_scheduled(
            &mut sched,
            &ids,
            std::slice::from_ref(&stream),
            false,
        );
        for h in 0..N_HEADS {
            assert_eq!(
                got[0][h].data(),
                expected[h].as_slice(),
                "{precision:?} threads={threads} head {h}: scheduled \
                 compaction stream diverged"
            );
        }
    }
}

#[test]
fn compaction_evict_restore_bitwise_f64() {
    check_compaction_resume(Precision::F64, "compact_resume_f64");
}

#[test]
fn compaction_evict_restore_bitwise_f32() {
    check_compaction_resume(Precision::F32, "compact_resume_f32");
}

// ----------------------------------- (d3) snapshot schema compatibility

#[test]
fn snapshot_v2_files_still_load_and_serve() {
    use darkformer::checkpoint::{Checkpoint, Tensor};
    use darkformer::rfa::serve::snapshot::{
        session_checkpoint, session_from_checkpoint,
    };

    // An online session past two boundaries, so the v3 snapshot carries
    // a live maintained factor.
    let rc = ResampleConfig {
        epoch_positions: CHUNK as u64,
        max_epochs: 4,
        shrinkage: 0.05,
        compaction: None,
    };
    let dir = snapshot_dir("v2_load");
    let mut pool = SessionPool::new(cfg_resample(
        iso_est(),
        Precision::F64,
        1,
        0,
        dir,
        rc,
    ));
    let id = pool.create_session(1717).unwrap();
    let stream = stream_inputs(9800);
    for r in 0..2 {
        pool.session_mut(id)
            .unwrap()
            .step(&slice_heads(&stream, r * CHUNK, (r + 1) * CHUNK), CHUNK);
    }
    let ck = session_checkpoint(pool.session_mut(id).unwrap());
    assert!(
        ck.get("head0/online/chol_factor").is_some(),
        "post-boundary v3 snapshot must carry the maintained factor"
    );

    // Rewrite as a v2 file: drop every v3-only tensor, stamp version 2.
    let mut v2 = Checkpoint::new();
    for name in ck.names().cloned().collect::<Vec<_>>() {
        if name.contains("/online/chol_")
            || name.ends_with("/online/compactions")
            || name.contains("resample/compaction")
            || name == "session/version"
        {
            continue;
        }
        v2.insert(name.clone(), ck.get(&name).unwrap().clone());
    }
    v2.insert("session/version", Tensor::from_u32(vec![1], &[2]));

    let mut restored =
        session_from_checkpoint(&v2).expect("v2 snapshot must load");
    assert_eq!(restored.position(), (2 * CHUNK) as u64);
    assert_eq!(restored.head_epochs(), vec![2; N_HEADS]);
    // The factor state comes back fresh (v2 predates it) and the session
    // keeps serving: the next boundary refreshes from scratch.
    for r in 2..N_REQUESTS {
        let outs = restored
            .step(&slice_heads(&stream, r * CHUNK, (r + 1) * CHUNK), CHUNK);
        assert_eq!(outs.len(), N_HEADS);
    }
    assert_eq!(restored.head_epochs(), vec![N_REQUESTS as u64; N_HEADS]);
}

#[test]
fn snapshot_v1_files_still_load_bitwise() {
    use darkformer::checkpoint::{Checkpoint, Tensor};
    use darkformer::rfa::serve::snapshot::{
        session_checkpoint, session_from_checkpoint,
    };

    // A static-bank session's v3 snapshot differs from a v1 file only in
    // the version stamp and the `session/resample` flag — strip both to
    // reconstruct a genuine pre-resampling file.
    let dir = snapshot_dir("v1_load");
    let mut pool =
        SessionPool::new(cfg(iso_est(), Precision::F64, 1, 0, dir));
    let id = pool.create_session(2323).unwrap();
    let stream = stream_inputs(9801);
    pool.session_mut(id)
        .unwrap()
        .step(&slice_heads(&stream, 0, CHUNK), CHUNK);
    let ck = session_checkpoint(pool.session_mut(id).unwrap());

    let mut v1 = Checkpoint::new();
    for name in ck.names().cloned().collect::<Vec<_>>() {
        if name == "session/version" || name.starts_with("session/resample")
        {
            continue;
        }
        v1.insert(name.clone(), ck.get(&name).unwrap().clone());
    }
    v1.insert("session/version", Tensor::from_u32(vec![1], &[1]));

    let mut restored =
        session_from_checkpoint(&v1).expect("v1 snapshot must load");
    assert!(restored.resample_config().is_none());
    // Continuing the stream reproduces the uninterrupted serial
    // reference bit for bit — v1 restoration is still lossless.
    let mut got: Vec<Vec<f64>> = vec![Vec::new(); N_HEADS];
    for r in 1..N_REQUESTS {
        let outs = restored
            .step(&slice_heads(&stream, r * CHUNK, (r + 1) * CHUNK), CHUNK);
        for (h, out) in outs.iter().enumerate() {
            got[h].extend_from_slice(out.to_f64().data());
        }
    }
    let expected = serial_reference(&iso_est(), 2323, &stream, Precision::F64);
    for h in 0..N_HEADS {
        assert_eq!(
            got[h].as_slice(),
            &expected[h].data()[CHUNK * DV..],
            "head {h}: v1-restored session diverged from the serial \
             reference"
        );
    }
}

// --------------------------------------------- (e) scheduler bugfixes

#[test]
fn submit_rejects_zero_row_and_headless_requests() {
    let dir = snapshot_dir("zero_rows");
    let mut pool =
        SessionPool::new(cfg(iso_est(), Precision::F64, 1, 0, dir));
    let id = pool.create_session(3).unwrap();
    let mut sched = BatchScheduler::new(pool);
    let stream = stream_inputs(9700);

    let err = sched
        .submit(StepRequest { session_id: id, heads: Vec::new() })
        .unwrap_err();
    assert!(format!("{err}").contains("no heads"), "got: {err}");

    let err = sched
        .submit(StepRequest {
            session_id: id,
            heads: slice_heads(&stream, 0, 0),
        })
        .unwrap_err();
    assert!(format!("{err}").contains("zero positions"), "got: {err}");
    assert_eq!(sched.pending_len(), 0, "rejected requests must not queue");
}

#[test]
fn tick_surfaces_responses_when_post_batch_budget_fails() {
    // The post-completion budget re-enforcement is bookkeeping: if it
    // fails, the tick's finished responses must still surface and the
    // error must be retried on the next tick — not lose a batch of work.
    let budget = one_session_bytes(Precision::F64, "budget_probe");
    let dir = snapshot_dir("deferred_budget");
    let mut pool =
        SessionPool::new(cfg(iso_est(), Precision::F64, 1, budget, dir));
    let s0 = pool.create_session(41).unwrap();
    let s1 = pool.create_session(43).unwrap(); // evicts s0
    assert_eq!(pool.evicted_count(), 1);
    let stream0 = stream_inputs(9400);
    let stream1 = stream_inputs(9401);
    let mut sched = BatchScheduler::new(pool);
    // Submit s1 first: the tick touches sessions in arrival order, so
    // after the batch the LRU victim is s1 — the resident session whose
    // snapshot path is free to block up front (s0's path holds its
    // eviction file until fault-in consumes it).
    sched
        .submit(StepRequest {
            session_id: s1,
            heads: slice_heads(&stream1, 0, CHUNK),
        })
        .unwrap();
    sched
        .submit(StepRequest {
            session_id: s0,
            heads: slice_heads(&stream0, 0, CHUNK),
        })
        .unwrap();

    // Block the eviction write with a directory squatting on the exact
    // snapshot path (File::create on a directory fails even as root).
    let block = sched.pool().snapshot_path(s1);
    std::fs::create_dir_all(&block).unwrap();

    let done = sched.tick().expect("a completed batch must not fail");
    assert_eq!(done, 2, "both requests completed");
    let responses = sched.poll_responses();
    assert_eq!(responses.len(), 2, "completed responses were lost");
    assert_eq!(sched.pending_len(), 0);
    let err = sched
        .budget_error()
        .expect("budget failure must be deferred, not dropped");
    assert!(
        format!("{err:#}").contains("evicting session"),
        "got: {err:#}"
    );
    // The failed write is visible in the health report: degraded mode
    // (eviction suspended), deferred budget, a counted failure, no
    // quarantine (writes never quarantine sessions).
    let health = sched.health();
    assert!(health.degraded, "failed eviction write must degrade the pool");
    assert!(health.deferred_budget);
    assert!(health.snapshot_failures >= 1);
    assert_eq!(health.quarantined, 0);
    // The surfaced outputs are the correct ones.
    for resp in &responses {
        let (seed, stream) = if resp.session_id == s0 {
            (41u64, &stream0)
        } else {
            (43u64, &stream1)
        };
        let expected = serial_reference(
            &iso_est(),
            seed,
            &slice_heads(stream, 0, CHUNK),
            Precision::F64,
        );
        for (h, out) in resp.outputs.iter().enumerate() {
            assert_eq!(
                out.as_f64().unwrap(),
                &expected[h],
                "session {} head {h}: deferred-budget tick corrupted \
                 its outputs",
                resp.session_id
            );
        }
    }

    // Heal the path: the next tick retries the deferred re-enforcement
    // before batching and brings the pool back under budget.
    std::fs::remove_dir(&block).unwrap();
    assert_eq!(sched.tick().unwrap(), 0);
    assert!(
        sched.budget_error().is_none(),
        "healed snapshot dir must clear the deferred error"
    );
    assert!(sched.pool().resident_bytes() <= budget);
    let health = sched.health();
    assert!(!health.degraded, "successful write must clear degraded mode");
    assert!(!health.deferred_budget);
}

#[test]
fn failed_fault_in_preserves_order_and_later_outputs() {
    // Failure containment + error-path determinism: a tick whose
    // fault-in fails for one session still completes every healthy
    // session (the acceptance criterion "one failing session never
    // blocks the batch"), requeues the failing session's request at its
    // queue front, and — once the snapshot heals — the whole run is
    // bitwise identical to a run that never failed.
    let budget = one_session_bytes(Precision::F64, "fault_probe");
    let streams = [stream_inputs(9500), stream_inputs(9501)];
    let seeds = [61u64, 67];

    let run = |fault: bool, tag: &str| -> Vec<Vec<Matrix>> {
        let dir = snapshot_dir(tag);
        let mut pool =
            SessionPool::new(cfg(iso_est(), Precision::F64, 1, budget, dir));
        let ids: Vec<u64> =
            seeds.iter().map(|s| pool.create_session(*s).unwrap()).collect();
        // Creating session 1 evicted session 0: its snapshot is on disk.
        assert_eq!(pool.evicted_count(), 1);
        let snap = pool.snapshot_path(ids[0]);
        let mut sched = BatchScheduler::new(pool);
        for r in 0..N_REQUESTS {
            for (id, stream) in ids.iter().zip(&streams) {
                let heads = slice_heads(stream, r * CHUNK, (r + 1) * CHUNK);
                sched
                    .submit(StepRequest { session_id: *id, heads })
                    .unwrap();
            }
        }
        let mut responses = Vec::new();
        if fault {
            let pending = sched.pending_len();
            let queued = sched.queued_seqs();
            // Corrupt the snapshot: the first tick's fault-in of
            // session 0 fails (a persistent, CRC-classified error).
            let original = std::fs::read(&snap).unwrap();
            let mut bad = original.clone();
            let mid = bad.len() / 2;
            bad[mid] ^= 0x10;
            std::fs::write(&snap, &bad).unwrap();
            let done = sched
                .tick()
                .expect("one faulting session must not fail the tick");
            assert_eq!(
                done, 1,
                "the healthy session must complete in the same tick"
            );
            responses = sched.poll_responses();
            assert_eq!(responses.len(), 1);
            assert_eq!(responses[0].session_id, ids[1]);
            // The faulted request went back to its queue front; nothing
            // was lost or reordered for session 0.
            assert_eq!(sched.pending_len(), pending - 1);
            assert_eq!(
                sched.queued_seqs().get(&ids[0]),
                queued.get(&ids[0]),
                "failed session's queue order changed"
            );
            let health = sched.health();
            assert!(health.snapshot_failures >= 1);
            assert_eq!(health.quarantined, 0, "one failure must not quarantine");
            assert!(
                !health.degraded,
                "a read failure must not suspend eviction"
            );
            // Heal the snapshot and continue normally: the requeued
            // request retries after its (tick-counted) backoff.
            std::fs::write(&snap, &original).unwrap();
        }
        let outcome = sched.run_until_idle();
        responses.extend(outcome.into_result().unwrap());
        reassemble_streams(responses, &ids)
    };

    let clean = run(false, "fault_clean");
    let healed = run(true, "fault_healed");
    for s in 0..2 {
        for h in 0..N_HEADS {
            assert_eq!(
                clean[s][h], healed[s][h],
                "session {s} head {h}: recovery after a failed fault-in \
                 is not bitwise identical to a clean run"
            );
        }
    }
}

#[test]
fn close_session_unlinks_snapshots_and_drops_state() {
    // Snapshot accretion bugfix: closing a session must reclaim its
    // disk snapshot, not just its memory — a churned pool's snapshot
    // directory ends empty once every session is closed.
    let budget = one_session_bytes(Precision::F64, "close_probe");
    let dir = snapshot_dir("close_churn");
    let mut pool = SessionPool::new(cfg(
        iso_est(),
        Precision::F64,
        1,
        budget,
        dir.clone(),
    ));
    let ids: Vec<u64> =
        (0..3u64).map(|s| pool.create_session(100 + s).unwrap()).collect();
    assert_eq!(pool.evicted_count(), 2);
    let files = |dir: &PathBuf| std::fs::read_dir(dir).unwrap().count();
    assert_eq!(files(&dir), 2, "two eviction snapshots on disk");

    // Through the scheduler, close also drops the session's queued work.
    let stream = stream_inputs(9600);
    let mut sched = BatchScheduler::new(pool);
    sched
        .submit(StepRequest {
            session_id: ids[2],
            heads: slice_heads(&stream, 0, CHUNK),
        })
        .unwrap();
    assert_eq!(sched.pending_len(), 1);
    sched.close_session(ids[2]).unwrap();
    assert_eq!(sched.pending_len(), 0, "closed session left queued work");
    assert_eq!(sched.tick().unwrap(), 0, "orphaned work after close");

    for &id in &ids[..2] {
        sched.close_session(id).unwrap();
    }
    let mut pool = sched.into_pool();
    assert_eq!(pool.resident_count(), 0);
    assert_eq!(pool.evicted_count(), 0);
    assert!(ids.iter().all(|&id| !pool.contains(id)));
    assert_eq!(
        files(&dir),
        0,
        "closed sessions must leave no snapshot files behind"
    );
    let err = pool.close_session(999).unwrap_err();
    assert!(format!("{err}").contains("no session"), "got: {err}");
}
