//! Offline shim of the `anyhow` API.
//!
//! The build environment has no network access and no crates.io mirror, so
//! this path dependency re-implements the (small) subset of `anyhow` the
//! coordinator uses: [`Error`], [`Result`], the [`Context`] extension
//! trait for `Result` and `Option`, and the `anyhow!` / `bail!` /
//! `ensure!` macros. Error values carry a context chain; `{e}` prints the
//! outermost message and `{e:#}` the full `outer: ...: root` chain, like
//! the real crate.
//!
//! Swapping in upstream `anyhow` is a one-line change in the root
//! `Cargo.toml`; no call sites depend on shim-only behavior.

use std::fmt;

/// Error with a human-readable context chain (outermost first).
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { chain: vec![message.to_string()] }
    }

    /// Push a new outermost context frame.
    fn wrap(mut self, context: String) -> Self {
        self.chain.insert(0, context);
        self
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The root cause (innermost message).
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}`: the full chain, outermost to root cause.
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

/// Like `anyhow::Error`, the shim error deliberately does NOT implement
/// `std::error::Error`, so the blanket `From` below stays coherent.
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut source = e.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>`: `std::result::Result` with a defaulted error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Context-attachment extension for `Result` and `Option`.
pub trait Context<T> {
    /// Wrap the error (or `None`) with a fixed context message.
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;

    /// Wrap the error (or `None`) with a lazily-built context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().wrap(context.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().wrap(f().to_string()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::core::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: `{}`", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn display_plain_and_alternate() {
        let e: Error = Err::<(), _>(io_err())
            .context("loading manifest")
            .unwrap_err();
        assert_eq!(format!("{e}"), "loading manifest");
        assert_eq!(format!("{e:#}"), "loading manifest: missing file");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("nothing there").unwrap_err();
        assert_eq!(format!("{e}"), "nothing there");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<u32> {
            let n: u32 = "12".parse()?;
            Ok(n)
        }
        assert_eq!(inner().unwrap(), 12);
    }

    #[test]
    fn macros_build_errors() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 3 {
                bail!("three is right out");
            }
            Ok(x)
        }
        assert_eq!(f(2).unwrap(), 2);
        assert_eq!(format!("{}", f(3).unwrap_err()), "three is right out");
        assert_eq!(format!("{}", f(11).unwrap_err()), "x too big: 11");
    }

    #[test]
    fn context_stacks_on_anyhow_errors() {
        let e = Err::<(), _>(anyhow!("root"))
            .context("mid")
            .context("outer")
            .unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: mid: root");
        assert_eq!(e.root_cause(), "root");
        assert_eq!(e.chain().count(), 3);
    }

    #[test]
    fn ensure_without_message() {
        fn f() -> Result<()> {
            ensure!(1 + 1 == 3);
            Ok(())
        }
        assert!(format!("{}", f().unwrap_err()).contains("1 + 1 == 3"));
    }
}
