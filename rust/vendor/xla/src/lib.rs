//! Offline stub of the `xla` PJRT bindings.
//!
//! The container that builds this repo has no XLA/PJRT shared libraries,
//! so the real `xla` crate cannot link. This stub keeps the `--features
//! pjrt` configuration *compiling* offline:
//!
//! * [`Literal`] is an honest host-side tensor container — `scalar`,
//!   `vec1`, `reshape`, `to_vec`, `get_first_element`, `array_shape`,
//!   `ty` and `decompose_tuple` all work, so host-only code paths (and
//!   their unit tests) behave normally.
//! * [`PjRtClient::cpu`] returns an error explaining that this build has
//!   no PJRT backend; nothing that needs a device can be constructed, so
//!   the compile/execute surface is unreachable stubs.
//!
//! Deployments with real artifacts point the `xla` dependency at the
//! actual bindings instead of this directory.

use std::fmt;

/// Element types the coordinator exchanges with artifacts.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    S8,
    S32,
    S64,
    U8,
    U32,
    U64,
    F16,
    Bf16,
    F32,
    F64,
    Tuple,
}

/// Stub error type; `Debug`-formatted by callers.
pub struct XlaError {
    pub msg: String,
}

impl XlaError {
    fn new(msg: impl Into<String>) -> Self {
        XlaError { msg: msg.into() }
    }
}

impl fmt::Debug for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "XlaError({})", self.msg)
    }
}

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl std::error::Error for XlaError {}

pub type Result<T> = std::result::Result<T, XlaError>;

const NO_BACKEND: &str = "PJRT backend unavailable: this binary was built \
against the vendored stub `xla` crate (rust/vendor/xla). Point the `xla` \
dependency at the real bindings to execute AOT artifacts.";

/// Typed storage behind a [`Literal`].
#[doc(hidden)]
#[derive(Debug, Clone)]
pub enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
    U32(Vec<u32>),
    Tuple(Vec<Literal>),
}

/// Host element types a [`Literal`] can hold.
pub trait NativeType: Copy {
    const TY: ElementType;
    fn into_data(v: Vec<Self>) -> Data;
    fn from_data(d: &Data) -> Option<Vec<Self>>;
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
    fn into_data(v: Vec<Self>) -> Data {
        Data::F32(v)
    }
    fn from_data(d: &Data) -> Option<Vec<Self>> {
        match d {
            Data::F32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
    fn into_data(v: Vec<Self>) -> Data {
        Data::I32(v)
    }
    fn from_data(d: &Data) -> Option<Vec<Self>> {
        match d {
            Data::I32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl NativeType for u32 {
    const TY: ElementType = ElementType::U32;
    fn into_data(v: Vec<Self>) -> Data {
        Data::U32(v)
    }
    fn from_data(d: &Data) -> Option<Vec<Self>> {
        match d {
            Data::U32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

/// Array shape: dimensions in row-major order.
#[derive(Debug, Clone)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Host-side literal (tensor of scalars, or a tuple of literals).
#[derive(Debug, Clone)]
pub struct Literal {
    ty: ElementType,
    dims: Vec<i64>,
    data: Data,
}

impl Literal {
    /// Rank-0 literal.
    pub fn scalar<T: NativeType>(v: T) -> Literal {
        Literal { ty: T::TY, dims: Vec::new(), data: T::into_data(vec![v]) }
    }

    /// Rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
        Literal {
            ty: T::TY,
            dims: vec![v.len() as i64],
            data: T::into_data(v.to_vec()),
        }
    }

    fn element_count(&self) -> i64 {
        self.dims.iter().product()
    }

    /// Same data, new dimensions (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let new_count: i64 = dims.iter().product();
        if new_count != self.element_count() {
            return Err(XlaError::new(format!(
                "reshape: {:?} has {} elements, target {:?} has {}",
                self.dims,
                self.element_count(),
                dims,
                new_count
            )));
        }
        Ok(Literal { ty: self.ty, dims: dims.to_vec(), data: self.data.clone() })
    }

    /// Element type of the literal.
    pub fn ty(&self) -> Result<ElementType> {
        Ok(self.ty)
    }

    /// Shape of an array (non-tuple) literal.
    pub fn array_shape(&self) -> Result<ArrayShape> {
        match self.data {
            Data::Tuple(_) => Err(XlaError::new("array_shape of a tuple literal")),
            _ => Ok(ArrayShape { dims: self.dims.clone() }),
        }
    }

    /// Copy the elements out as a host vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::from_data(&self.data).ok_or_else(|| {
            XlaError::new(format!(
                "to_vec: literal holds {:?}, requested {:?}",
                self.ty,
                T::TY
            ))
        })
    }

    /// First element of the flattened literal.
    pub fn get_first_element<T: NativeType>(&self) -> Result<T> {
        let v = self.to_vec::<T>()?;
        v.first().copied().ok_or_else(|| XlaError::new("empty literal"))
    }

    /// Split a tuple literal into its components.
    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        match std::mem::replace(&mut self.data, Data::Tuple(Vec::new())) {
            Data::Tuple(items) => Ok(items),
            other => {
                self.data = other;
                Err(XlaError::new("decompose_tuple of a non-tuple literal"))
            }
        }
    }
}

/// Parsed HLO module handle (never constructible offline).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(XlaError::new(NO_BACKEND))
    }
}

/// Computation wrapper over a parsed module.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Device-resident buffer handle (never constructible offline).
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(XlaError::new(NO_BACKEND))
    }
}

/// Compiled executable handle (never constructible offline).
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(XlaError::new(NO_BACKEND))
    }
}

/// PJRT client. The stub has no backend, so construction always fails
/// with an actionable message.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(XlaError::new(NO_BACKEND))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(
        &self,
        _computation: &XlaComputation,
    ) -> Result<PjRtLoadedExecutable> {
        Err(XlaError::new(NO_BACKEND))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_round_trip() {
        let lit = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let r = lit.reshape(&[2, 3]).unwrap();
        assert_eq!(r.array_shape().unwrap().dims(), &[2, 3]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(r.ty().unwrap(), ElementType::F32);
        assert!(r.reshape(&[7]).is_err());
    }

    #[test]
    fn scalars_and_first_element() {
        assert_eq!(Literal::scalar(7u32).get_first_element::<u32>().unwrap(), 7);
        assert_eq!(Literal::scalar(1.5f32).get_first_element::<f32>().unwrap(), 1.5);
        assert!(Literal::scalar(1i32).get_first_element::<f32>().is_err());
    }

    #[test]
    fn client_is_unavailable() {
        let err = PjRtClient::cpu().err().unwrap();
        assert!(format!("{err:?}").contains("PJRT backend unavailable"));
    }
}
